//! ML substrate benches: forest / GBT train+predict, estimator service.
//!
//! Run: `cargo bench --bench ml_benches`

use repro::charac::{characterize, Backend, InputSet};
use repro::coordinator::{BatchOptions, EstimatorService};
use repro::ml::forest::{ForestParams, RandomForest};
use repro::ml::gbt::{GbtParams, GradientBoostedTrees};
use repro::operator::{AxoConfig, Operator};
use repro::surrogate::{GbtSurrogate, Surrogate};
use repro::util::bench::Bench;
use repro::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let mut b = Bench::new().with_budget(Duration::from_millis(150), Duration::from_secs(1));

    // Dataset: 1024 sampled mul8 designs (the GA's fitness substrate).
    let op = Operator::MUL8;
    let inputs = InputSet::exhaustive(op);
    let mut rng = Rng::seed_from_u64(3);
    let cfgs = AxoConfig::sample_unique(36, 1024, &mut rng);
    let ds = characterize(op, &cfgs, &inputs, &Backend::Native).unwrap();
    let x: Vec<f64> = ds
        .configs
        .iter()
        .flat_map(|c| c.to_bits_f32().into_iter().map(|v| v as f64))
        .collect();
    let y_err: Vec<f64> = ds.behav.iter().map(|m| m.avg_abs_rel_err).collect();
    let y_bits: Vec<f64> = ds
        .configs
        .iter()
        .flat_map(|c| c.to_bits_f32().into_iter().map(|v| v as f64))
        .collect();

    // Training costs.
    b.bench("gbt/train_1024x36_120stages", || {
        GradientBoostedTrees::fit(&x, 36, &y_err, GbtParams::default()).unwrap()
    });
    let forest_params = ForestParams { n_trees: 25, ..Default::default() };
    b.bench("forest/train_1024x36_to_36out_25trees", || {
        RandomForest::fit(&x, 36, &y_bits, 36, forest_params.clone()).unwrap()
    });

    // Prediction costs (the GA hot loop).
    let gbt = GradientBoostedTrees::fit(&x, 36, &y_err, GbtParams::default()).unwrap();
    let row = &x[..36];
    b.bench("gbt/predict_row", || gbt.predict_row(row));
    let forest = RandomForest::fit(&x, 36, &y_bits, 36, forest_params).unwrap();
    b.bench("forest/predict_bits_row", || forest.predict_bits_row(row));

    let surrogate = GbtSurrogate::train(&ds, GbtParams::default()).unwrap();
    let batch = &ds.configs[..256];
    b.bench("surrogate/gbt_predict_256", || surrogate.predict(batch).unwrap());

    // Batching service round-trip (single client; measures overhead).
    let svc = EstimatorService::spawn(
        Arc::new(GbtSurrogate::train(&ds, GbtParams::default()).unwrap()),
        BatchOptions { max_batch: 256, max_wait: Duration::from_micros(200) },
    );
    let req: Vec<AxoConfig> = ds.configs[..100].to_vec();
    b.bench("service/roundtrip_100cfg", || svc.predict(req.clone()).unwrap());

    // PJRT MLP estimator, when compiled in and artifacts are built.
    #[cfg(feature = "pjrt")]
    {
        let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if Backend::pjrt_ready(&artifacts) {
            use repro::runtime::{MlpExec, Runtime};
            use repro::surrogate::PjrtSurrogate;
            let rt = Runtime::cpu(&artifacts).unwrap();
            let mlp = PjrtSurrogate::new(MlpExec::new(&rt, "estimator_mul8").unwrap()).unwrap();
            b.bench("surrogate/pjrt_mlp_predict_256", || mlp.predict(batch).unwrap());
        } else {
            println!(
                "(PJRT not ready — artifacts missing or stub xla linked; skipping PJRT MLP bench)"
            );
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(built without the `pjrt` feature — skipping PJRT MLP bench)");

    b.finish();
}
