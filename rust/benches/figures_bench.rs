//! End-to-end figure regeneration timings (quick-scale settings).
//!
//! One timed pass per cheap paper figure: this is the "how long does it
//! take to reproduce the paper's analysis" number recorded in
//! EXPERIMENTS.md. The GA-heavy figures (15–18) are exercised by
//! `cargo run --example end_to_end_dse` instead — they take minutes, not
//! bench-loop material.
//!
//! Run: `cargo bench --bench figures_bench`

use repro::expcfg::ExperimentConfig;
use repro::report::{figures, tables, Harness};
use repro::util::bench::Bench;
use repro::util::tempdir::TempDir;
use std::time::Duration;

fn main() {
    let tmp = TempDir::new().unwrap();
    let cfg = ExperimentConfig {
        train_samples: 800, // quick-scale H_CHAR sample
        conss: repro::expcfg::ConssConfig { forest_trees: Some(10), ..Default::default() },
        out_dir: tmp.path().to_path_buf(),
        ..Default::default()
    };
    let harness = Harness::new(cfg);

    // Datasets are cached inside the harness after the first call, so the
    // first bench includes characterization and the rest measure analysis.
    let mut b = Bench::new().with_budget(Duration::from_millis(10), Duration::from_millis(500));
    b.bench("figures/tab2_operators", || tables::tab2_operators(&harness).unwrap());
    b.bench("figures/fig1_clustering(add8+add12)", || {
        figures::fig1_clustering_adders(&harness).unwrap()
    });
    b.bench("figures/fig2_trends", || figures::fig2_trends_subsampled(&harness).unwrap());
    b.bench("figures/fig5_trends", || figures::fig5_trends_all_adders(&harness).unwrap());
    b.bench("figures/fig10_clustering(mul)", || {
        figures::fig10_clustering_multipliers(&harness).unwrap()
    });
    b.bench("figures/fig11_distance_hists", || {
        figures::fig11_distance_distributions(&harness).unwrap()
    });
    b.bench("figures/fig12_matching", || figures::fig12_matching(&harness).unwrap());
    b.bench("figures/fig13_conss_accuracy", || {
        figures::fig13_conss_accuracy(&harness).unwrap()
    });
    b.finish();
}
