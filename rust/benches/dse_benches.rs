//! DSE engine benches: hypervolume, non-dominated sort, GA generations
//! (the paper's Fig. 15/16 machinery; feeds EXPERIMENTS.md §Perf L3).
//!
//! Run: `cargo bench --bench dse_benches`

use repro::dse::{
    hypervolume2d, nsga2, pareto_front_indices, Constraints, GaOptions, NsgaRunner,
    Objectives,
};
use repro::operator::AxoConfig;
use repro::util::bench::Bench;
use repro::util::rng::Rng;
use std::time::Duration;

fn random_points(n: usize, seed: u64) -> Vec<Objectives> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..n).map(|_| [rng.gen_f64(), rng.gen_f64()]).collect()
}

fn main() {
    let mut b = Bench::new().with_budget(Duration::from_millis(150), Duration::from_secs(1));

    for n in [100usize, 1000, 10_000] {
        let pts = random_points(n, n as u64);
        b.bench(&format!("pareto/front_indices_{n}"), || pareto_front_indices(&pts));
        b.bench(&format!("hypervolume/2d_{n}"), || hypervolume2d(&pts, [1.0, 1.0]));
    }

    let pts = random_points(200, 9);
    let constraints = Constraints::new(0.8, 0.8).unwrap();
    b.bench("nsga2/fast_nondominated_sort_200", || {
        nsga2::fast_non_dominated_sort(&pts, Some(&constraints))
    });
    b.bench("nsga2/select_200_to_100", || nsga2::select(&pts, Some(&constraints), 100));

    // GA end-to-end with a cheap analytic fitness: isolates engine cost.
    let fitness = |cfgs: &[AxoConfig]| -> repro::error::Result<Vec<Objectives>> {
        Ok(cfgs
            .iter()
            .map(|c| {
                let ones = c.count_kept() as f64 / c.len() as f64;
                [1.0 - ones, ones * ones]
            })
            .collect())
    };
    for (pop, gens) in [(100usize, 10u32), (100, 50)] {
        b.bench(&format!("ga/36bit_pop{pop}_gens{gens}"), || {
            let runner = NsgaRunner::new(
                GaOptions { pop_size: pop, generations: gens, seed: 7, ..Default::default() },
                constraints,
            );
            runner.run(36, &fitness, &[]).unwrap()
        });
    }

    // Paper-scale single run: pop 100 × 250 generations (Fig. 15 setting).
    let mut paper = Bench::new().with_budget(Duration::from_millis(10), Duration::from_secs(2));
    paper.bench("ga/paper_scale_pop100_gens250", || {
        let runner = NsgaRunner::new(
            GaOptions { pop_size: 100, generations: 250, seed: 7, ..Default::default() },
            constraints,
        );
        runner.run(36, &fitness, &[]).unwrap()
    });

    b.finish();
}
