//! Characterization throughput benches (feeds EXPERIMENTS.md §Perf L3 and
//! the Table II reproduction cost numbers).
//!
//! Run: `cargo bench --bench charac_benches`

use repro::charac::{behav, characterize, Backend, InputSet};
use repro::operator::{adder, multiplier, AxoConfig, Operator};
use repro::util::bench::Bench;
use repro::util::rng::Rng;

fn main() {
    let mut b = Bench::new();

    // Scalar operator model evaluation (the native substrate's inner loop).
    let cfg8 = AxoConfig::new(0b1011_0111, 8).unwrap();
    b.bench("adder8/eval_one", || adder::eval_one(&cfg8, 173, 92));
    let cfgm = AxoConfig::new(0x5_BEEF_CAFE, 36).unwrap();
    b.bench("mul8/eval_one", || multiplier::eval_one(8, &cfgm, -77, 103));

    // Term-matrix construction (shared operand of the PJRT kernel).
    let (a4, b4) = multiplier::exhaustive_inputs(4);
    b.bench("mul4/term_matrix_256", || multiplier::term_matrix(4, &a4, &b4));

    // Batched native BEHAV characterization.
    let inputs8 = InputSet::exhaustive(Operator::ADD8);
    let a8: Vec<u32> = inputs8.a.iter().map(|&v| v as u32).collect();
    let b8: Vec<u32> = inputs8.b.iter().map(|&v| v as u32).collect();
    let cfgs64: Vec<AxoConfig> = {
        let mut rng = Rng::seed_from_u64(1);
        AxoConfig::sample_unique(8, 64, &mut rng)
    };
    b.bench("adder8/behav_64cfg_x65536", || behav::adder_behav(&cfgs64, &a8, &b8));

    let inputs_m8 = InputSet::exhaustive(Operator::MUL8);
    let terms = multiplier::term_matrix(8, &inputs_m8.a, &inputs_m8.b);
    let mcfgs: Vec<AxoConfig> = {
        let mut rng = Rng::seed_from_u64(2);
        AxoConfig::sample_unique(36, 64, &mut rng)
    };
    b.bench("mul8/behav_64cfg_x65536", || behav::mult_behav(&mcfgs, &terms, 36));

    // Full pipeline (BEHAV + synthesis estimator) per Table II row.
    let inputs4 = InputSet::exhaustive(Operator::ADD4);
    b.bench("pipeline/add4_exhaustive(15)", || {
        let cfgs: Vec<AxoConfig> = AxoConfig::enumerate(4).collect();
        characterize(Operator::ADD4, &cfgs, &inputs4, &Backend::Native).unwrap()
    });
    let inputs_m4 = InputSet::exhaustive(Operator::MUL4);
    b.bench("pipeline/mul4_exhaustive(1023)", || {
        let cfgs: Vec<AxoConfig> = AxoConfig::enumerate(10).collect();
        characterize(Operator::MUL4, &cfgs, &inputs_m4, &Backend::Native).unwrap()
    });

    // PJRT path, when compiled in (`--features pjrt`) and artifacts built:
    // the AOT Pallas kernel.
    #[cfg(feature = "pjrt")]
    {
        let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if Backend::pjrt_ready(&artifacts) {
            use repro::runtime::{AxoEvalExec, Runtime};
            let rt = Runtime::cpu(&artifacts).unwrap();
            let exec = AxoEvalExec::new(&rt, Operator::MUL4, &inputs_m4).unwrap();
            b.bench("pjrt/mul4_axo_eval_64cfg_x256", || {
                exec.eval_configs(&mcfgs.iter().map(|_| AxoConfig::accurate(10)).take(64).collect::<Vec<_>>())
                    .unwrap()
            });
            let exec8 = AxoEvalExec::new(&rt, Operator::MUL8, &inputs_m8).unwrap();
            b.bench("pjrt/mul8_axo_eval_64cfg_x65536", || {
                exec8.eval_configs(&mcfgs[..64.min(mcfgs.len())]).unwrap()
            });
        } else {
            println!(
                "(PJRT not ready — artifacts missing or stub xla linked; skipping PJRT benches)"
            );
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(built without the `pjrt` feature — skipping PJRT benches)");

    b.finish();
}
