//! Characterization throughput benches (feeds EXPERIMENTS.md §Perf L3 and
//! the Table II reproduction cost numbers).
//!
//! The BEHAV cases run every batch twice — scalar oracle vs the bit-sliced
//! default — and the suite stamps `BENCH_charac.json` with a `speedup`
//! object (scalar mean / bitslice mean per pair) so the bit-slicing win is
//! recorded in the perf trajectory. The PPA cases do the same for the
//! config-parallel plane estimator (`ppa_speedup`: scalar mean / plane
//! mean, plus the fused single-pass pipeline vs an inline two-pass
//! rebuild). CI's bench-smoke job uploads the stamp.
//!
//! Run: `cargo bench --bench charac_benches`

use repro::charac::behav::{
    adder_behav_with, mult_behav, mult_behav_bitslice, native_behav_with,
};
use repro::charac::{
    characterize, characterize_sharded_as, characterize_timed, Backend,
    BehavBackend, Dataset, InputSet, PpaBackend,
};
use repro::operator::{adder, multiplier, AxoConfig, Operator};
use repro::synth::ppa_batch_with;
use repro::util::bench::Bench;
use repro::util::json::Json;
use repro::util::rng::Rng;

/// (stamp key, scalar bench, bitslice bench) — the pairs the `speedup`
/// object is computed from.
const SPEEDUP_PAIRS: [(&str, &str, &str); 4] = [
    (
        "adder8_behav",
        "adder8/behav_scalar_64cfg_x65536",
        "adder8/behav_bitslice_64cfg_x65536",
    ),
    (
        "mul8_behav",
        "mul8/behav_scalar_64cfg_x65536",
        "mul8/behav_bitslice_64cfg_x65536",
    ),
    (
        "add8_sharded",
        "charac/add8_sharded64_scalar",
        "charac/add8_sharded64_bitslice",
    ),
    (
        "mul8_sharded",
        "charac/mul8_sharded64_scalar",
        "charac/mul8_sharded64_bitslice",
    ),
];

/// (stamp key, baseline bench, optimized bench) — the pairs the
/// `ppa_speedup` object is computed from: per-config scalar estimation vs
/// the 64-lane plane path, and the fused single-pass pipeline vs an
/// inline BEHAV-then-PPA two-pass over the same batch.
const PPA_SPEEDUP_PAIRS: [(&str, &str, &str); 3] = [
    (
        "add12_ppa",
        "synth/add12_ppa_scalar_1024cfg",
        "synth/add12_ppa_plane_1024cfg",
    ),
    (
        "mul8_ppa",
        "synth/mul8_ppa_scalar_1024cfg",
        "synth/mul8_ppa_plane_1024cfg",
    ),
    (
        "mul8_fused",
        "pipeline/mul8_two_pass_64cfg",
        "pipeline/mul8_fused_64cfg",
    ),
];

fn main() {
    let mut b = Bench::new();

    // Scalar operator model evaluation (the native substrate's inner loop).
    let cfg8 = AxoConfig::new(0b1011_0111, 8).unwrap();
    b.bench("adder8/eval_one", || adder::eval_one(&cfg8, 173, 92));
    let cfgm = AxoConfig::new(0x5_BEEF_CAFE, 36).unwrap();
    b.bench("mul8/eval_one", || multiplier::eval_one(8, &cfgm, -77, 103));

    // Term-matrix construction (shared operand of the PJRT kernel).
    let (a4, b4) = multiplier::exhaustive_inputs(4);
    b.bench("mul4/term_matrix_256", || multiplier::term_matrix(4, &a4, &b4));

    // Batched native BEHAV characterization, scalar oracle vs bit-sliced
    // default over identical batches (cold: no pipeline, no estimator).
    let inputs8 = InputSet::exhaustive(Operator::ADD8);
    let a8: Vec<u32> = inputs8.a.iter().map(|&v| v as u32).collect();
    let b8: Vec<u32> = inputs8.b.iter().map(|&v| v as u32).collect();
    let cfgs64: Vec<AxoConfig> = {
        let mut rng = Rng::seed_from_u64(1);
        AxoConfig::sample_unique(8, 64, &mut rng)
    };
    b.bench("adder8/behav_scalar_64cfg_x65536", || {
        adder_behav_with(&cfgs64, &a8, &b8, BehavBackend::Scalar)
    });
    b.bench("adder8/behav_bitslice_64cfg_x65536", || {
        adder_behav_with(&cfgs64, &a8, &b8, BehavBackend::Bitslice)
    });

    let inputs_m8 = InputSet::exhaustive(Operator::MUL8);
    let terms = multiplier::term_matrix(8, &inputs_m8.a, &inputs_m8.b);
    let mcfgs: Vec<AxoConfig> = {
        let mut rng = Rng::seed_from_u64(2);
        AxoConfig::sample_unique(36, 64, &mut rng)
    };
    b.bench("mul8/behav_scalar_64cfg_x65536", || {
        mult_behav(&mcfgs, &terms, 36)
    });
    b.bench("mul8/behav_bitslice_64cfg_x65536", || {
        mult_behav_bitslice(8, &mcfgs, &inputs_m8.a, &inputs_m8.b)
    });

    // The same comparison through the sharded pipeline (BEHAV + synthesis
    // estimator + dataset assembly), the path the engine cache pays.
    b.bench("charac/add8_sharded64_scalar", || {
        characterize_sharded_as(
            Operator::ADD8,
            &cfgs64,
            &inputs8,
            16,
            BehavBackend::Scalar,
        )
        .unwrap()
    });
    b.bench("charac/add8_sharded64_bitslice", || {
        characterize_sharded_as(
            Operator::ADD8,
            &cfgs64,
            &inputs8,
            16,
            BehavBackend::Bitslice,
        )
        .unwrap()
    });
    b.bench("charac/mul8_sharded64_scalar", || {
        characterize_sharded_as(
            Operator::MUL8,
            &mcfgs,
            &inputs_m8,
            16,
            BehavBackend::Scalar,
        )
        .unwrap()
    });
    b.bench("charac/mul8_sharded64_bitslice", || {
        characterize_sharded_as(
            Operator::MUL8,
            &mcfgs,
            &inputs_m8,
            16,
            BehavBackend::Bitslice,
        )
        .unwrap()
    });

    // Pure synthesis estimation: per-config scalar oracle vs the 64-lane
    // config-parallel plane path (the `ppa_speedup` stamp inputs).
    let ppa_adds: Vec<AxoConfig> = {
        let mut rng = Rng::seed_from_u64(3);
        AxoConfig::sample_unique(12, 1024, &mut rng)
    };
    b.bench("synth/add12_ppa_scalar_1024cfg", || {
        ppa_batch_with(Operator::ADD12, &ppa_adds, PpaBackend::Scalar)
    });
    b.bench("synth/add12_ppa_plane_1024cfg", || {
        ppa_batch_with(Operator::ADD12, &ppa_adds, PpaBackend::Plane)
    });
    let ppa_muls: Vec<AxoConfig> = {
        let mut rng = Rng::seed_from_u64(4);
        AxoConfig::sample_unique(36, 1024, &mut rng)
    };
    b.bench("synth/mul8_ppa_scalar_1024cfg", || {
        ppa_batch_with(Operator::MUL8, &ppa_muls, PpaBackend::Scalar)
    });
    b.bench("synth/mul8_ppa_plane_1024cfg", || {
        ppa_batch_with(Operator::MUL8, &ppa_muls, PpaBackend::Plane)
    });

    // Fused single-pass characterization vs an inline two-pass rebuild of
    // the same dataset (a whole-batch BEHAV fan-out, then a second
    // whole-batch PPA fan-out) — what the pipeline did before fusion.
    b.bench("pipeline/mul8_two_pass_64cfg", || {
        let behav = native_behav_with(
            Operator::MUL8,
            &mcfgs,
            &inputs_m8,
            BehavBackend::Bitslice,
        );
        let ppa = ppa_batch_with(Operator::MUL8, &mcfgs, PpaBackend::Plane);
        Dataset::new(Operator::MUL8, mcfgs.clone(), behav, ppa).unwrap()
    });
    b.bench("pipeline/mul8_fused_64cfg", || {
        characterize_timed(
            Operator::MUL8,
            &mcfgs,
            &inputs_m8,
            BehavBackend::Bitslice,
            PpaBackend::Plane,
        )
        .unwrap()
    });

    // Full pipeline (BEHAV + synthesis estimator) per Table II row.
    let inputs4 = InputSet::exhaustive(Operator::ADD4);
    b.bench("pipeline/add4_exhaustive(15)", || {
        let cfgs: Vec<AxoConfig> = AxoConfig::enumerate(4).collect();
        characterize(Operator::ADD4, &cfgs, &inputs4, &Backend::Native).unwrap()
    });
    let inputs_m4 = InputSet::exhaustive(Operator::MUL4);
    b.bench("pipeline/mul4_exhaustive(1023)", || {
        let cfgs: Vec<AxoConfig> = AxoConfig::enumerate(10).collect();
        characterize(Operator::MUL4, &cfgs, &inputs_m4, &Backend::Native).unwrap()
    });

    // PJRT path, when compiled in (`--features pjrt`) and artifacts built:
    // the AOT Pallas kernel.
    #[cfg(feature = "pjrt")]
    {
        let artifacts = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if Backend::pjrt_ready(&artifacts) {
            use repro::runtime::{AxoEvalExec, Runtime};
            let rt = Runtime::cpu(&artifacts).unwrap();
            let exec = AxoEvalExec::new(&rt, Operator::MUL4, &inputs_m4).unwrap();
            b.bench("pjrt/mul4_axo_eval_64cfg_x256", || {
                exec.eval_configs(&mcfgs.iter().map(|_| AxoConfig::accurate(10)).take(64).collect::<Vec<_>>())
                    .unwrap()
            });
            let exec8 = AxoEvalExec::new(&rt, Operator::MUL8, &inputs_m8).unwrap();
            b.bench("pjrt/mul8_axo_eval_64cfg_x65536", || {
                exec8.eval_configs(&mcfgs[..64.min(mcfgs.len())]).unwrap()
            });
        } else {
            println!(
                "(PJRT not ready — artifacts missing or stub xla linked; skipping PJRT benches)"
            );
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("(built without the `pjrt` feature — skipping PJRT benches)");

    b.finish();

    // Stamp the results plus the scalar/bitslice speedups.
    let mean = |name: &str| {
        b.results().iter().find(|r| r.name == name).map(|r| r.mean_ns)
    };
    let mut speedup = std::collections::BTreeMap::new();
    for (key, scalar, bitslice) in SPEEDUP_PAIRS {
        if let (Some(s), Some(v)) = (mean(scalar), mean(bitslice)) {
            if v > 0.0 {
                let ratio = s / v;
                println!("speedup {key:<14} {ratio:.2}x (scalar/bitslice)");
                speedup.insert(key.to_string(), Json::Num(ratio));
            }
        }
    }
    let mut ppa_speedup = std::collections::BTreeMap::new();
    for (key, baseline, optimized) in PPA_SPEEDUP_PAIRS {
        if let (Some(s), Some(v)) = (mean(baseline), mean(optimized)) {
            if v > 0.0 {
                let ratio = s / v;
                println!("ppa_speedup {key:<14} {ratio:.2}x (baseline/optimized)");
                ppa_speedup.insert(key.to_string(), Json::Num(ratio));
            }
        }
    }
    let mut stamp = b.to_json();
    if let Json::Obj(map) = &mut stamp {
        map.insert("speedup".into(), Json::Obj(speedup));
        map.insert("ppa_speedup".into(), Json::Obj(ppa_speedup));
    }
    let path = std::path::Path::new("BENCH_charac.json");
    std::fs::write(path, stamp.to_string()).expect("write BENCH_charac.json");
    println!("wrote {}", path.display());
}
