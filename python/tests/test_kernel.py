"""Pallas kernels vs pure-jnp ref vs canonical numpy model.

The CORE correctness signal for L1: every kernel must agree with ``ref.py``
(allclose) and ``ref.py`` must agree with ``operator_model.py`` exactly.
Hypothesis sweeps shapes and configuration contents.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import operator_model as om
from compile.kernels import axo_eval, mlp, ref


def finalize(raw, t):
    r = np.asarray(raw)
    return np.stack([r[:, 0] / t, r[:, 1] / t, r[:, 2], r[:, 3] / t], axis=1)


# ---------------------------------------------------------------------------
# Adder kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_bits,bsz,t", [(4, 16, 256), (8, 8, 1024), (6, 4, 4096)])
def test_adder_kernel_matches_ref(n_bits, bsz, t):
    rng = np.random.default_rng(1)
    cfgs = rng.integers(0, 2, size=(bsz, n_bits)).astype(np.int32)
    a = rng.integers(0, 1 << n_bits, size=(t, 1)).astype(np.int32)
    b = rng.integers(0, 1 << n_bits, size=(t, 1)).astype(np.int32)
    out = axo_eval.adder_eval_kernel(
        jnp.asarray(cfgs), jnp.asarray(a), jnp.asarray(b), config_block=4, input_tile=256
    )
    want = ref.adder_eval_ref(jnp.asarray(cfgs), jnp.asarray(a[:, 0]), jnp.asarray(b[:, 0]))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


@given(
    n_bits=st.integers(2, 12),
    bsz=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_adder_kernel_matches_numpy_model(n_bits, bsz, seed):
    rng = np.random.default_rng(seed)
    cfgs = rng.integers(0, 2, size=(bsz, n_bits)).astype(np.int32)
    t = 256
    a = rng.integers(0, 1 << n_bits, size=t).astype(np.int64)
    b = rng.integers(0, 1 << n_bits, size=t).astype(np.int64)
    out = axo_eval.adder_eval_kernel(
        jnp.asarray(cfgs),
        jnp.asarray(a[:, None].astype(np.int32)),
        jnp.asarray(b[:, None].astype(np.int32)),
        config_block=2,
        input_tile=128,
    )
    want = om.behav_metrics(om.adder_exact(a, b), om.adder_eval(cfgs, a, b))
    np.testing.assert_allclose(finalize(out, t), want, rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# Multiplier kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m_bits,bsz", [(2, 4), (4, 16), (4, 64)])
def test_mult_kernel_matches_numpy_model(m_bits, bsz):
    rng = np.random.default_rng(2)
    l = om.mult_config_len(m_bits)
    cfgs = rng.integers(0, 2, size=(bsz, l)).astype(np.int64)
    a, b = om.mult_inputs(m_bits)
    terms = om.mult_term_matrix(m_bits, a, b)
    t = terms.shape[0]
    out = axo_eval.mult_eval_kernel(
        jnp.asarray(cfgs.astype(np.float32)),
        jnp.asarray(terms.astype(np.float32)),
        jnp.asarray(terms.sum(axis=1).astype(np.float32)[:, None]),
        config_block=4,
        input_tile=64,
    )
    want = om.behav_metrics(om.mult_exact(terms), om.mult_eval(cfgs, terms))
    np.testing.assert_allclose(finalize(out, t), want, rtol=1e-5, atol=1e-7)


@given(seed=st.integers(0, 2**31 - 1), tile=st.sampled_from([64, 256, 1024]))
@settings(max_examples=10, deadline=None)
def test_mult8_kernel_matches_ref_sampled_inputs(seed, tile):
    rng = np.random.default_rng(seed)
    cfgs = rng.integers(0, 2, size=(8, 36)).astype(np.float32)
    a = rng.integers(-128, 128, size=1024, dtype=np.int64)
    b = rng.integers(-128, 128, size=1024, dtype=np.int64)
    terms = om.mult_term_matrix(8, a, b).astype(np.float32)
    exact = terms.sum(axis=1)[:, None]
    out = axo_eval.mult_eval_kernel(
        jnp.asarray(cfgs), jnp.asarray(terms), jnp.asarray(exact),
        config_block=8, input_tile=tile,
    )
    want = ref.mult_eval_ref(jnp.asarray(cfgs), jnp.asarray(terms))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5)


def test_mult_kernel_accurate_config_zero_error():
    a, b = om.mult_inputs(4)
    terms = om.mult_term_matrix(4, a, b).astype(np.float32)
    cfgs = np.ones((4, 10), dtype=np.float32)
    out = axo_eval.mult_eval_kernel(
        jnp.asarray(cfgs), jnp.asarray(terms),
        jnp.asarray(terms.sum(axis=1)[:, None]),
    )
    np.testing.assert_array_equal(np.asarray(out), np.zeros((4, 4)))


# ---------------------------------------------------------------------------
# MLP kernel
# ---------------------------------------------------------------------------


@given(
    bsz=st.sampled_from([32, 64, 128]),
    fin=st.integers(2, 40),
    hidden=st.sampled_from([16, 64]),
    fout=st.integers(1, 36),
    sigmoid=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_mlp_kernel_matches_ref(bsz, fin, hidden, fout, sigmoid, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(bsz, fin)).astype(np.float32)
    params = [
        (rng.normal(size=(fin, hidden)).astype(np.float32) * 0.3,
         rng.normal(size=(hidden,)).astype(np.float32) * 0.1),
        (rng.normal(size=(hidden, fout)).astype(np.float32) * 0.3,
         rng.normal(size=(fout,)).astype(np.float32) * 0.1),
    ]
    jp = [(jnp.asarray(w), jnp.asarray(b)) for w, b in params]
    out = mlp.mlp_forward(jnp.asarray(x), jp, final_sigmoid=sigmoid, batch_tile=32)
    want = ref.mlp_ref(jnp.asarray(x), jp, final_sigmoid=sigmoid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_mlp_single_layer_linear_identity():
    x = np.eye(8, dtype=np.float32)
    w = np.eye(8, dtype=np.float32)
    b = np.zeros(8, dtype=np.float32)
    out = mlp.linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), batch_tile=8)
    np.testing.assert_array_equal(np.asarray(out), x)
