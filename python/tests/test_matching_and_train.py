"""Distance matching (build-time mirror) and MLP training smoke tests."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import matching
from compile import operator_model as om
from compile import train


def test_minmax_scale_range_and_constant_columns():
    x = np.array([[0.0, 5.0], [10.0, 5.0], [5.0, 5.0]])
    s = matching.minmax_scale(x)
    np.testing.assert_allclose(s[:, 0], [0.0, 1.0, 0.5])
    np.testing.assert_allclose(s[:, 1], 0.0)  # constant column maps to 0


def test_match_euclidean_identity():
    """When H == L (same scaled metric cloud), every point matches itself."""
    rng = np.random.default_rng(0)
    m = rng.uniform(size=(50, 2))
    idx = matching.match_euclidean(m, m)
    np.testing.assert_array_equal(idx, np.arange(50))


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_match_euclidean_is_argmin(seed):
    rng = np.random.default_rng(seed)
    l = rng.uniform(size=(20, 2))
    h = rng.uniform(size=(31, 2))
    idx = matching.match_euclidean(l, h)
    ls = matching.minmax_scale(l)
    hs = matching.minmax_scale(h)
    for i in range(len(h)):
        d = ((hs[i] - ls) ** 2).sum(axis=1)
        assert d[idx[i]] <= d.min() + 1e-12


def test_conss_dataset_noise_replication():
    l_cfg = om.all_configs(4)
    h_cfg = om.all_configs(6)
    rng = np.random.default_rng(1)
    l_m = rng.uniform(size=(len(l_cfg), 2))
    h_m = rng.uniform(size=(len(h_cfg), 2))
    x, y = matching.conss_dataset(l_cfg, l_m, h_cfg, h_m, noise_bits=2)
    assert x.shape == (len(h_cfg) * 4, 4 + 2)
    assert y.shape == (len(h_cfg) * 4, 6)
    # Noise suffixes: each matched pair appears with all 4 noise values.
    base = x[:, :4]
    assert set(map(tuple, x[:, 4:])) == {(0, 0), (1, 0), (0, 1), (1, 1)}
    # Outputs are valid 0/1 configurations.
    assert set(np.unique(y)) <= {0.0, 1.0}
    assert set(np.unique(base)) <= {0.0, 1.0}


def test_sample_mul8_configs_unique_nonzero_deterministic():
    a = train.sample_mul8_configs(100, seed=5)
    b = train.sample_mul8_configs(100, seed=5)
    np.testing.assert_array_equal(a, b)
    uints = {om.config_to_uint(c) for c in a}
    assert len(uints) == 100 and 0 not in uints


def test_characterize_mul_chunking_consistent():
    cfgs = train.sample_mul8_configs(8, seed=3)
    full = train.characterize_mul(cfgs, 8, chunk=8)
    chunked = train.characterize_mul(cfgs, 8, chunk=3)
    np.testing.assert_allclose(full, chunked)


def test_train_estimator_loss_decreases_tiny():
    cfgs = train.sample_mul8_configs(256, seed=11)
    targets = train.characterize_mul(cfgs, 8)
    res = train.train_estimator(cfgs, targets, epochs=8, batch=64)
    assert res.history[-1] < res.history[0]
    assert res.x_min is not None and len(res.x_min) == 2


def test_train_conss_loss_decreases_tiny():
    h_cfgs = train.sample_mul8_configs(64, seed=12)
    h_m = train.characterize_mul(h_cfgs, 8)
    res = train.train_conss(epochs=4, batch=64, h_configs=h_cfgs, h_metrics=h_m)
    assert res.history[-1] < res.history[0]
