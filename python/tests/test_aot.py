"""AOT export machinery: weights container, golden fixtures, HLO lowering."""

import json
import struct
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as L2
from compile import operator_model as om


def read_weights_bin(path):
    """Reference reader for the AXOW container (mirrors rust runtime)."""
    data = path.read_bytes()
    assert data[:4] == b"AXOW"
    version, n = struct.unpack_from("<II", data, 4)
    assert version == 1
    pos = 12
    out = {}
    for _ in range(n):
        (name_len,) = struct.unpack_from("<I", data, pos)
        pos += 4
        name = data[pos : pos + name_len].decode()
        pos += name_len
        (ndim,) = struct.unpack_from("<I", data, pos)
        pos += 4
        dims = struct.unpack_from(f"<{ndim}I", data, pos)
        pos += 4 * ndim
        count = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(data, dtype="<f4", count=count, offset=pos)
        pos += 4 * count
        out[name] = arr.reshape(dims)
    assert pos == len(data)
    return out


def test_weights_bin_roundtrip(tmp_path):
    w = np.arange(6, dtype=np.float32).reshape(2, 3)
    b = np.array([0.5, -1.5], dtype=np.float32)
    p = tmp_path / "w.bin"
    aot.write_weights_bin(p, [("layer.w", w), ("layer.b", b)])
    back = read_weights_bin(p)
    np.testing.assert_array_equal(back["layer.w"], w)
    np.testing.assert_array_equal(back["layer.b"], b)


def test_golden_configs_include_accurate_and_are_unique():
    for length in (4, 8, 10, 36):
        vals = aot.golden_configs(length)
        assert (1 << length) - 1 in vals  # accurate
        assert len(set(vals)) == len(vals)
        assert all(1 <= v < (1 << length) for v in vals)


def test_hlo_text_lowering_smoke():
    cfg = jax.ShapeDtypeStruct((4, 3), jnp.int32)
    col = jax.ShapeDtypeStruct((16, 1), jnp.int32)
    lowered = jax.jit(L2.adder_eval).lower(cfg, col, col)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "s32[4,3]" in text  # entry signature keeps our shapes


def test_small_export_writes_consistent_manifest(tmp_path):
    manifest = {"version": 1, "executables": {}}
    aot.export_adder("add4", 4, 16, 256, tmp_path, manifest)
    entry = manifest["executables"]["axo_eval_add4"]
    assert (tmp_path / entry["hlo"]).exists()
    assert entry["inputs"][0]["shape"] == [16, 4]
    assert entry["output"]["shape"] == [16, 4]
    aot.export_mult("mul4", 4, 8, 256, tmp_path, manifest)
    entry = manifest["executables"]["axo_eval_mul4"]
    assert entry["config_len"] == 10
    assert entry["inputs"][1]["shape"] == [256, 10]


@pytest.mark.skipif(
    not (Path(__file__).resolve().parents[2] / "artifacts/manifest.json").exists(),
    reason="artifacts not built",
)
def test_built_artifacts_are_complete_and_coherent():
    root = Path(__file__).resolve().parents[2] / "artifacts"
    manifest = json.loads((root / "manifest.json").read_text())
    assert manifest["version"] == 1
    expected = {
        "axo_eval_add4",
        "axo_eval_add8",
        "axo_eval_add12",
        "axo_eval_mul4",
        "axo_eval_mul8",
        "estimator_mul8",
        "conss_mul4to8",
    }
    assert expected <= set(manifest["executables"])
    for name, entry in manifest["executables"].items():
        assert (root / entry["hlo"]).exists(), name
        if entry.get("weights"):
            w = read_weights_bin(root / entry["weights"])
            assert list(w) == entry["param_order"]
    est = manifest["executables"]["estimator_mul8"]
    assert est["targets"] == ["pdplut", "avg_abs_rel_err"]
    assert len(est["target_min"]) == 2
    # Golden fixture coherence: metrics recompute identically.
    golden = json.loads((root / "golden_behav.json").read_text())
    entry = golden["operators"]["mul4"]
    uints = [int(v) for v in entry["configs_uint"]]
    cfgs = np.stack([om.config_from_uint(v, 10) for v in uints])
    a, b = om.mult_inputs(4)
    terms = om.mult_term_matrix(4, a, b)
    behav = om.behav_metrics(om.mult_exact(terms), om.mult_eval(cfgs, terms))
    np.testing.assert_allclose(behav, np.array(entry["behav"]), rtol=1e-12)


@pytest.mark.skipif(
    not (Path(__file__).resolve().parents[2] / "artifacts/inputs_add12.bin").exists(),
    reason="artifacts not built",
)
def test_add12_input_file_matches_generator():
    root = Path(__file__).resolve().parents[2] / "artifacts"
    data = (root / "inputs_add12.bin").read_bytes()
    assert data[:4] == b"AXIN"
    version, n = struct.unpack_from("<II", data, 4)
    assert version == 1
    a = np.frombuffer(data, dtype="<u4", count=n, offset=12)
    b = np.frombuffer(data, dtype="<u4", count=n, offset=12 + 4 * n)
    ga, gb = om.adder_inputs(12)
    np.testing.assert_array_equal(a, ga.astype(np.uint32))
    np.testing.assert_array_equal(b, gb.astype(np.uint32))
