"""Canonical operator model: bit-exactness and structural invariants."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import operator_model as om


# ---------------------------------------------------------------------------
# Adder
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_bits", [3, 4, 8])
def test_adder_accurate_is_exact_exhaustive(n_bits):
    a, b = om.adder_inputs(n_bits, max_samples=1 << (2 * n_bits))
    cfg = np.ones((1, n_bits), dtype=np.int32)
    out = om.adder_eval(cfg, a, b)
    np.testing.assert_array_equal(out[0], a.astype(np.int64) + b)


@given(
    n_bits=st.integers(4, 12),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_adder_accurate_is_exact_sampled(n_bits, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 1 << n_bits, size=64, dtype=np.uint32)
    b = rng.integers(0, 1 << n_bits, size=64, dtype=np.uint32)
    cfg = np.ones((1, n_bits), dtype=np.int32)
    np.testing.assert_array_equal(om.adder_eval(cfg, a, b)[0], a.astype(np.int64) + b)


def test_adder_removal_rule_bit0():
    """l_0 = 0 forces s_0 = c_0 = 0 and c_1 = b_0 (DESIGN.md model)."""
    cfg = np.array([[0, 1, 1]], dtype=np.int32)
    # a=1, b=1: exact 2. With l0 removed: s0=0, c1=b0=1, remaining bits add
    # a'=0,b'=0 with carry-in 1 -> out = 2. Still exact here.
    out = om.adder_eval(cfg, np.array([1]), np.array([1]))
    assert out[0, 0] == 2
    # a=1, b=0: exact 1. s0 = 0, c1 = b0 = 0 -> out 0.
    out = om.adder_eval(cfg, np.array([1]), np.array([0]))
    assert out[0, 0] == 0


def test_adder_all_zero_config_output():
    """All LUTs removed: s_i = c_i where c propagates b bits shifted."""
    cfg = np.zeros((1, 4), dtype=np.int32)
    a = np.array([5])
    b = np.array([3])
    # c_0=0, s_0=0, c_{i+1}=b_i: out bits s_i = b_{i-1} -> out = (b << 1) & mask + carry-out b_3.
    out = om.adder_eval(cfg, a, b)
    assert out[0, 0] == ((3 << 1) & 0xF) | (((3 >> 3) & 1) << 4)


# ---------------------------------------------------------------------------
# Multiplier
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m_bits", [2, 3, 4])
def test_mult_terms_sum_to_exact_product_exhaustive(m_bits):
    a, b = om.mult_inputs(m_bits)
    terms = om.mult_term_matrix(m_bits, a, b)
    np.testing.assert_array_equal(terms.sum(axis=1), a * b)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_mult8_terms_sum_to_exact_product_sampled(seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(-128, 128, size=128, dtype=np.int64)
    b = rng.integers(-128, 128, size=128, dtype=np.int64)
    terms = om.mult_term_matrix(8, a, b)
    np.testing.assert_array_equal(terms.sum(axis=1), a * b)


def test_mult_accurate_config_is_exact():
    a, b = om.mult_inputs(4)
    terms = om.mult_term_matrix(4, a, b)
    cfg = np.ones((1, om.mult_config_len(4)), dtype=np.int32)
    np.testing.assert_array_equal(om.mult_eval(cfg, terms)[0], a * b)


def test_mult_pairs_order_and_len():
    assert om.mult_pairs(2) == [(0, 0), (0, 1), (1, 1)]
    assert om.mult_config_len(4) == 10
    assert om.mult_config_len(8) == 36  # Table II: 36-bit config string


def test_mult_single_removal_effect():
    """Removing pair (0,0) zeroes a0*b0: product loses exactly 1 when both odd."""
    m = 4
    a = np.array([3, 3, 2], dtype=np.int64)
    b = np.array([5, 4, 6], dtype=np.int64)
    terms = om.mult_term_matrix(m, a, b)
    cfg = np.ones((1, om.mult_config_len(m)), dtype=np.int32)
    cfg[0, 0] = 0  # pair (0,0)
    out = om.mult_eval(cfg, terms)[0]
    np.testing.assert_array_equal(out, a * b - (a & 1) * (b & 1))


# ---------------------------------------------------------------------------
# Configs / metrics
# ---------------------------------------------------------------------------


@given(length=st.integers(1, 36), value=st.integers(1, 2**36 - 1))
@settings(max_examples=50, deadline=None)
def test_config_uint_roundtrip(length, value):
    value %= 1 << length
    if value == 0:
        value = 1
    bits = om.config_from_uint(value, length)
    assert om.config_to_uint(bits) == value


def test_all_configs_excludes_zero():
    cfgs = om.all_configs(4)
    assert cfgs.shape == (15, 4)
    assert (cfgs.sum(axis=1) > 0).all()
    # Table II counts: 16 total designs - zero config = 15 usable; 8-bit: 255.
    assert om.all_configs(8).shape[0] == 255


def test_behav_metrics_zero_for_exact():
    exact = np.array([1, 2, 3, -4])
    approx = exact[None, :].copy()
    m = om.behav_metrics(exact, approx)
    np.testing.assert_array_equal(m, np.zeros((1, 4)))


def test_behav_metrics_known_values():
    exact = np.array([0, 2, -4])
    approx = np.array([[1, 1, -2]])
    m = om.behav_metrics(exact, approx)
    # errs: 1,1,2 ; rel: 1/1, 1/2, 2/4 ; max 2 ; prob 1.0
    np.testing.assert_allclose(m[0], [4 / 3, (1 + 0.5 + 0.5) / 3, 2.0, 1.0])


def test_adder_error_grows_with_significance():
    """Removing a more significant LUT yields larger avg abs error."""
    a, b = om.adder_inputs(8, max_samples=1 << 16)
    errs = []
    for k in (0, 3, 7):
        cfg = np.ones((1, 8), dtype=np.int32)
        cfg[0, k] = 0
        errs.append(om.characterize_adder(cfg, 8, a, b)[0, 0])
    assert errs[0] < errs[1] < errs[2]
