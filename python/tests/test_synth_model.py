"""Analytical synthesis estimator: pinned values + structural invariants."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import operator_model as om
from compile import synth_model as sm


def test_adder_accurate_pinned_values():
    cfg = np.ones((1, 8), dtype=np.int32)
    luts, cpd, power, pdp, pdplut = sm.adder_ppa(cfg)[0]
    assert luts == 8
    np.testing.assert_allclose(cpd, sm.T_NET_NS + sm.T_LUT_NS + sm.T_CARRY_NS * 8)
    # act_i = 0.5 + (i+1)/(4*8); sum = 4 + (1+...+8)/32 = 4 + 36/32
    np.testing.assert_allclose(power, sm.P_BASE_MW + sm.P_LUT_MW * (4 + 36 / 32))
    np.testing.assert_allclose(pdp, power * cpd)
    np.testing.assert_allclose(pdplut, pdp * 8)


def test_adder_removal_breaks_carry_chain():
    full = sm.adder_ppa(np.ones((1, 8), dtype=np.int32))[0]
    mid = np.ones((1, 8), dtype=np.int32)
    mid[0, 4] = 0  # splits chain into runs of 4 and 3
    cut = sm.adder_ppa(mid)[0]
    assert cut[1] < full[1]  # CPD shrinks
    assert cut[0] == 7  # one fewer LUT
    assert cut[2] < full[2]  # less switching power


@given(n_bits=st.sampled_from([4, 8, 12]), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_adder_ppa_monotone_in_luts(n_bits, seed):
    """Removing any LUT never increases LUTs, power, CPD or PDPLUT."""
    rng = np.random.default_rng(seed)
    cfg = rng.integers(0, 2, size=(1, n_bits)).astype(np.int64)
    if cfg.sum() == 0:
        cfg[0, 0] = 1
    base = sm.adder_ppa(cfg)[0]
    ones = np.flatnonzero(cfg[0])
    k = ones[rng.integers(len(ones))]
    cfg2 = cfg.copy()
    cfg2[0, k] = 0
    red = sm.adder_ppa(cfg2)[0]
    assert red[0] <= base[0] and red[1] <= base[1] and red[2] <= base[2]
    assert red[4] <= base[4]


def test_longest_run():
    bits = np.array([[1, 1, 0, 1, 1, 1], [0, 0, 0, 0, 0, 0], [1, 1, 1, 1, 1, 1]])
    np.testing.assert_array_equal(sm._longest_run(bits), [3, 0, 6])


def test_mult_accurate_pinned_values():
    m = 4
    cfg = np.ones((1, om.mult_config_len(m)), dtype=np.int32)
    luts, cpd, power, pdp, pdplut = sm.mult_ppa(cfg, m)[0]
    assert luts == 10 + 4
    # col heights for 4x4 pairs: col c height = #bits: cols 0..6
    # pairs (i,j): (0,0)c0 h1,(0,1)c1 h2,(0,2)c2 h2,(0,3)c3 h2,(1,1)c2 h1,
    # (1,2)c3 h2,(1,3)c4 h2,(2,2)c4 h1,(2,3)c5 h2,(3,3)c6 h1
    # heights: [1,2,3,4,3,2,1] -> hmax 4, depth=ceil(ln4/ln1.5)=ceil(3.42)=4
    depth = np.ceil(np.log(4.0) / np.log(1.5))
    np.testing.assert_allclose(cpd, sm.T_NET_NS + sm.T_LUT_NS * (1 + depth) + sm.T_CARRY_NS * 7)
    assert power > sm.P_BASE_MW
    np.testing.assert_allclose(pdplut, pdp * luts)


@given(m_bits=st.sampled_from([4, 8]), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_mult_ppa_monotone_in_luts(m_bits, seed):
    rng = np.random.default_rng(seed)
    l = om.mult_config_len(m_bits)
    cfg = rng.integers(0, 2, size=(1, l)).astype(np.int64)
    if cfg.sum() == 0:
        cfg[0, 0] = 1
    base = sm.mult_ppa(cfg, m_bits)[0]
    ones = np.flatnonzero(cfg[0])
    k = ones[rng.integers(len(ones))]
    cfg2 = cfg.copy()
    cfg2[0, k] = 0
    red = sm.mult_ppa(cfg2, m_bits)[0]
    assert red[0] <= base[0] and red[1] <= base[1] and red[2] <= base[2]


def test_mult_ppa_rejects_wrong_config_len():
    with pytest.raises(AssertionError):
        sm.mult_ppa(np.ones((1, 9), dtype=np.int64), 4)


def test_ppa_dispatch():
    cfg = np.ones((2, 8), dtype=np.int64)
    np.testing.assert_array_equal(sm.ppa(cfg, "adder", 8), sm.adder_ppa(cfg))
    cfgm = np.ones((2, 10), dtype=np.int64)
    np.testing.assert_array_equal(sm.ppa(cfgm, "mult", 4), sm.mult_ppa(cfgm, 4))
    with pytest.raises(ValueError):
        sm.ppa(cfg, "divider", 8)
