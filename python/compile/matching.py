"""Build-time mirror of distance-based matching (paper §IV-B).

Used only to assemble the training dataset for the ConSS generator MLP that
``aot.py`` exports.  The full matching machinery (all three distance
measures, signed variants, heat-maps) lives in ``rust/src/matching/``; this
mirror implements exactly the Euclidean variant the paper selects for
supersampling (§V-C) so the two implementations can be cross-checked via
``golden_behav.json`` matched-pair fixtures.

Pipeline: min-max scale the (PPA, BEHAV) metric pairs of the L_CHAR and
H_CHAR datasets *independently* (the paper compares scaled metric spaces,
Fig. 1b), then for every H configuration find the nearest L configuration;
each (L_CONFIG -> H_CONFIG) pair becomes an INP_SEQ -> OUT_SEQ training
sample, replicated 2^n times with n noise bits appended (Fig. 8).
"""

from __future__ import annotations

import numpy as np


def minmax_scale(x: np.ndarray) -> np.ndarray:
    """Column-wise min-max scaling to [0, 1]; constant columns map to 0."""
    lo = x.min(axis=0)
    hi = x.max(axis=0)
    span = np.where(hi > lo, hi - lo, 1.0)
    return (x - lo) / span


def match_euclidean(l_metrics: np.ndarray, h_metrics: np.ndarray) -> np.ndarray:
    """Index of the nearest L point (scaled Euclidean) for every H point.

    Args:
        l_metrics: (NL, 2) [PPA, BEHAV] of the low-bit-width dataset.
        h_metrics: (NH, 2) of the high-bit-width dataset.
    Returns:
        (NH,) int indices into the L dataset.
    """
    ls = minmax_scale(l_metrics)
    hs = minmax_scale(h_metrics)
    # (NH, NL) pairwise distances — datasets are small (<= ~10k x ~1k).
    d2 = ((hs[:, None, :] - ls[None, :, :]) ** 2).sum(axis=2)
    return d2.argmin(axis=1)


def conss_dataset(
    l_configs: np.ndarray,
    l_metrics: np.ndarray,
    h_configs: np.ndarray,
    h_metrics: np.ndarray,
    noise_bits: int,
    seed: int = 7,
) -> tuple[np.ndarray, np.ndarray]:
    """INP_SEQ -> OUT_SEQ training set with noise augmentation.

    Every matched (l, h) pair is replicated 2^noise_bits times, once per
    noise value, exactly as Fig. 8: the same OUT_SEQ is the target for every
    noise suffix, which teaches the model a noise-conditioned *distribution*
    of plausible H configurations once multiple h map to the same l.
    Rows are shuffled with the given seed.
    """
    idx = match_euclidean(l_metrics, h_metrics)
    reps = 1 << noise_bits
    xs, ys = [], []
    for h_row, l_row in enumerate(idx):
        base = l_configs[l_row].astype(np.float32)
        for noise in range(reps):
            nb = np.array(
                [(noise >> k) & 1 for k in range(noise_bits)], dtype=np.float32
            )
            xs.append(np.concatenate([base, nb]))
            ys.append(h_configs[h_row].astype(np.float32))
    x = np.stack(xs)
    y = np.stack(ys)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(x))
    return x[perm], y[perm]
