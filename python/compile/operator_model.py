"""Canonical LUT-level approximate-operator model (build-time mirror).

This module is the *single source of truth* on the Python side for the
AppAxO-style operator model used throughout AxOCS (paper Section III):

  * An operator implementation is an ordered bit tuple
    ``O_i(l_0, ..., l_{L-1})``, ``l = 1`` keeps the LUT, ``l = 0`` removes it.
  * The all-ones configuration is the accurate operator; the all-zeros
    configuration is excluded from every experiment (paper footnote 4).

Two operator families are modelled bit-exactly:

Unsigned N-bit adder (L = N)
    LUT *i* computes the propagate signal ``p_i = a_i XOR b_i`` feeding a
    carry chain.  The MUXCY selects ``c_{i+1} = c_i`` when ``p_i`` else the
    DI input ``b_i``; the XORCY produces ``s_i = p_i XOR c_i``.  Removing
    LUT *i* forces ``p_i = 0`` so that ``s_i = c_i`` and ``c_{i+1} = b_i``.
    With all LUTs present this is exactly a ripple-carry adder.

Signed M x M Baugh-Wooley multiplier (L = M(M+1)/2)
    LUT ``(i, j)``, ``i <= j``, generates the partial-product pair
    ``a_i b_j + a_j b_i`` (the single ``a_i b_i`` when ``i == j``) with the
    signed weight ``w_i w_j`` where ``w_i = -2^(M-1)`` for the sign bit and
    ``2^i`` otherwise.  Removing the LUT zeroes both partial products.  The
    sum of all pairs is exactly ``A * B`` for two's-complement operands, so
    the all-ones configuration is accurate by construction.
    L = 10 for 4x4 and L = 36 for 8x8, matching Table II of the paper.

The Rust crate re-implements the identical model in ``rust/src/operator/``;
``aot.py`` emits ``golden_behav.json`` from this module and the Rust test
suite checks both implementations against it.
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Configuration helpers
# ---------------------------------------------------------------------------


def mult_pairs(m: int) -> list[tuple[int, int]]:
    """Ordered (i, j), i <= j LUT index pairs for an MxM multiplier.

    Lexicographic order (i ascending, then j) — the Rust side uses the same
    order so configuration bit k means the same LUT in both languages.
    """
    return [(i, j) for i in range(m) for j in range(i, m)]


def mult_config_len(m: int) -> int:
    return m * (m + 1) // 2


def config_from_uint(value: int, length: int) -> np.ndarray:
    """Decode a UINT-encoded configuration (bit 0 == l_0) to a 0/1 vector."""
    return np.array([(value >> k) & 1 for k in range(length)], dtype=np.int32)


def config_to_uint(bits: np.ndarray) -> int:
    return int(sum(int(b) << k for k, b in enumerate(bits)))


def all_configs(length: int) -> np.ndarray:
    """All 2^length - 1 usable configurations (all-zeros excluded)."""
    vals = np.arange(1, 1 << length, dtype=np.int64)
    out = ((vals[:, None] >> np.arange(length)[None, :]) & 1).astype(np.int32)
    return out


# ---------------------------------------------------------------------------
# Input sets
# ---------------------------------------------------------------------------


def adder_inputs(n_bits: int, max_samples: int = 65536, seed: int = 2023):
    """Exhaustive (a, b) pairs when 2^(2n) <= max_samples, else seeded sample.

    Returns two uint32 arrays.  The sampled variant is persisted by aot.py
    (``inputs_add12.bin``) so the Rust pipeline consumes the identical set.
    """
    total = 1 << (2 * n_bits)
    if total <= max_samples:
        idx = np.arange(total, dtype=np.uint64)
    else:
        rng = np.random.default_rng(seed)
        idx = rng.integers(0, total, size=max_samples, dtype=np.uint64)
    a = (idx & ((1 << n_bits) - 1)).astype(np.uint32)
    b = (idx >> n_bits).astype(np.uint32)
    return a, b


def mult_inputs(m_bits: int):
    """Exhaustive signed (a, b) pairs for an MxM multiplier (M <= 8)."""
    n = 1 << m_bits
    vals = np.arange(n, dtype=np.int64)
    signed = np.where(vals >= n // 2, vals - n, vals).astype(np.int64)
    a = np.repeat(signed, n)
    b = np.tile(signed, n)
    return a, b


# ---------------------------------------------------------------------------
# Behavioral models
# ---------------------------------------------------------------------------


def adder_eval(configs: np.ndarray, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Approximate-sum outputs for a batch of adder configurations.

    Args:
        configs: (B, N) 0/1 int array.
        a, b:    (T,) unsigned operand arrays.
    Returns:
        (B, T) int64 approximate sums.
    """
    configs = np.asarray(configs, dtype=np.int64)
    n_bits = configs.shape[1]
    a = np.asarray(a, dtype=np.int64)[None, :]
    b = np.asarray(b, dtype=np.int64)[None, :]
    cfg = configs[:, :, None]  # (B, N, 1)
    carry = np.zeros((configs.shape[0], a.shape[1]), dtype=np.int64)
    out = np.zeros_like(carry)
    for i in range(n_bits):
        ai = (a >> i) & 1
        bi = (b >> i) & 1
        p = (ai ^ bi) * cfg[:, i, :]
        s = p ^ carry
        out = out + (s << i)
        carry = np.where(p == 1, carry, bi)
    out = out + (carry << n_bits)
    return out


def adder_exact(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.asarray(a, dtype=np.int64) + np.asarray(b, dtype=np.int64)


def mult_term_matrix(m_bits: int, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Per-LUT signed partial-product contributions.

    Returns (T, L) int64 where column k is LUT k's contribution to the exact
    product for each input pair; summing all columns reproduces ``a * b``.
    The batched approximate product is then the matmul ``configs @ terms.T``.
    """
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    n = 1 << m_bits
    au = np.where(a < 0, a + n, a)
    bu = np.where(b < 0, b + n, b)
    abits = ((au[:, None] >> np.arange(m_bits)[None, :]) & 1).astype(np.int64)
    bbits = ((bu[:, None] >> np.arange(m_bits)[None, :]) & 1).astype(np.int64)
    w = np.array(
        [-(1 << (m_bits - 1)) if i == m_bits - 1 else (1 << i) for i in range(m_bits)],
        dtype=np.int64,
    )
    pairs = mult_pairs(m_bits)
    terms = np.zeros((a.shape[0], len(pairs)), dtype=np.int64)
    for k, (i, j) in enumerate(pairs):
        if i == j:
            terms[:, k] = w[i] * w[j] * abits[:, i] * bbits[:, j]
        else:
            terms[:, k] = w[i] * w[j] * (
                abits[:, i] * bbits[:, j] + abits[:, j] * bbits[:, i]
            )
    return terms


def mult_eval(configs: np.ndarray, terms: np.ndarray) -> np.ndarray:
    """(B, T) approximate signed products from the term matrix."""
    configs = np.asarray(configs, dtype=np.int64)
    return configs @ terms.T


def mult_exact(terms: np.ndarray) -> np.ndarray:
    return terms.sum(axis=1)


# ---------------------------------------------------------------------------
# BEHAV metrics
# ---------------------------------------------------------------------------

BEHAV_METRICS = ("avg_abs_err", "avg_abs_rel_err", "max_abs_err", "err_prob")


def behav_metrics(exact: np.ndarray, approx: np.ndarray) -> np.ndarray:
    """Error metrics over the input set.

    ``avg_abs_rel_err`` uses ``|err| / max(|exact|, 1)`` — the divisor floor
    avoids division by zero at exact == 0 (same convention in Rust).

    Args:
        exact:  (T,) exact outputs.
        approx: (B, T) approximate outputs.
    Returns:
        (B, 4) float64: avg_abs_err, avg_abs_rel_err, max_abs_err, err_prob.
    """
    err = np.abs(exact[None, :].astype(np.float64) - approx.astype(np.float64))
    denom = np.maximum(np.abs(exact).astype(np.float64), 1.0)[None, :]
    return np.stack(
        [
            err.mean(axis=1),
            (err / denom).mean(axis=1),
            err.max(axis=1),
            (err > 0).mean(axis=1),
        ],
        axis=1,
    )


def characterize_adder(configs: np.ndarray, n_bits: int, a=None, b=None) -> np.ndarray:
    if a is None:
        a, b = adder_inputs(n_bits)
    return behav_metrics(adder_exact(a, b), adder_eval(configs, a, b))


def characterize_mult(configs: np.ndarray, m_bits: int, terms=None) -> np.ndarray:
    if terms is None:
        a, b = mult_inputs(m_bits)
        terms = mult_term_matrix(m_bits, a, b)
    return behav_metrics(mult_exact(terms), mult_eval(configs, terms))
