"""Analytical FPGA synthesis estimator (build-time mirror of rust/src/synth).

Substitutes Xilinx Vivado 19.2 targeting the Virtex-7 7VX330T (paper §V-A),
which is unavailable in this environment.  The estimator produces the same
PPA metric set the paper characterizes — LUT utilization, critical path
delay (CPD, ns), dynamic power (mW), PDP and PDPLUT — as deterministic
structural functions of the configuration:

  * LUT utilization counts retained removable LUTs plus the operator's
    fixed logic.
  * CPD follows a carry-chain timing model for adders (the longest run of
    consecutive retained propagate LUTs — removal *breaks* the carry chain,
    exactly the effect sub-adder truncation exploits) and a compressor-tree
    + final-adder model for multipliers.
  * Dynamic power is per-LUT switching activity times device coefficients,
    with activity increasing with bit significance (longer average carry
    ripple / larger partial products toggling).

Device coefficients approximate published Virtex-7 characteristics (LUT6
delay ~0.124 ns, carry hop ~0.042 ns, sub-mW per-LUT dynamic power at
moderate toggle rates).  Absolute values are plausible, but the reproduction
claims *shape* fidelity only (see DESIGN.md §2, substitution 1).

Every constant and formula here is mirrored exactly in
``rust/src/synth/``; ``golden_behav.json`` pins both.
"""

from __future__ import annotations

import numpy as np

from . import operator_model as om

# Virtex-7-like device coefficients (shared with rust/src/synth/device.rs).
T_LUT_NS = 0.124  # LUT6 logic delay
T_CARRY_NS = 0.042  # one CARRY4 hop (per bit)
T_NET_NS = 0.458  # fixed routing + IOB overhead on the critical path
P_BASE_MW = 0.050  # clock-tree / fixed logic dynamic power
P_LUT_MW = 0.350  # per-LUT dynamic power at activity 1.0

PPA_METRICS = ("luts", "cpd_ns", "power_mw", "pdp", "pdplut")


# ---------------------------------------------------------------------------
# Unsigned adder
# ---------------------------------------------------------------------------


def _longest_run(bits: np.ndarray) -> np.ndarray:
    """Longest run of consecutive ones per row of a (B, N) 0/1 matrix."""
    best = np.zeros(bits.shape[0], dtype=np.int64)
    cur = np.zeros(bits.shape[0], dtype=np.int64)
    for i in range(bits.shape[1]):
        cur = (cur + 1) * bits[:, i]
        best = np.maximum(best, cur)
    return best


def adder_ppa(configs: np.ndarray) -> np.ndarray:
    """(B, 5) PPA metrics for unsigned adder configurations.

    CPD = T_NET + T_LUT + T_CARRY * R where R is the longest run of
    consecutive retained LUTs: a removed LUT *regenerates* the carry
    (c_{i+1} = b_i), cutting the ripple path.
    Activity of LUT i: act_i = 0.5 + (i + 1) / (4 N) — propagate toggles at
    0.5 for uniform inputs plus a significance-growing carry term.
    """
    configs = np.asarray(configs, dtype=np.int64)
    n = configs.shape[1]
    luts = configs.sum(axis=1).astype(np.float64)
    run = _longest_run(configs).astype(np.float64)
    cpd = T_NET_NS + T_LUT_NS + T_CARRY_NS * run
    act = 0.5 + (np.arange(n, dtype=np.float64) + 1.0) / (4.0 * n)
    power = P_BASE_MW + P_LUT_MW * (configs.astype(np.float64) @ act)
    pdp = power * cpd
    return np.stack([luts, cpd, power, pdp, pdp * luts], axis=1)


# ---------------------------------------------------------------------------
# Signed Baugh-Wooley multiplier
# ---------------------------------------------------------------------------


def mult_ppa(configs: np.ndarray, m_bits: int) -> np.ndarray:
    """(B, 5) PPA metrics for signed MxM multiplier configurations.

    Fixed logic: M LUT-equivalents of final carry-propagate adder.
    Column c height h_c = retained partial-product bits at weight 2^c
    (pair (i, j) adds 2 bits to column i+j when i < j, 1 when i == j).
    Compressor-tree depth = ceil(log_1.5(max_c h_c)) (Dadda-style 3:2
    reduction), CPD = T_NET + T_LUT * (1 + depth) + T_CARRY * span where
    span is the active-column range feeding the final adder.
    Activity of LUT (i, j): (2 if i < j else 1) * (0.3 + 0.4 (i+j)/(2M-2)).
    """
    configs = np.asarray(configs, dtype=np.int64)
    pairs = om.mult_pairs(m_bits)
    assert configs.shape[1] == len(pairs)
    b = configs.shape[0]
    n_cols = 2 * m_bits - 1

    heights = np.zeros((b, n_cols), dtype=np.int64)
    act = np.zeros(len(pairs), dtype=np.float64)
    for k, (i, j) in enumerate(pairs):
        w = 2 if i < j else 1
        heights[:, i + j] += w * configs[:, k]
        act[k] = w * (0.3 + 0.4 * (i + j) / (2 * m_bits - 2))

    luts = configs.sum(axis=1).astype(np.float64) + m_bits
    hmax = heights.max(axis=1).astype(np.float64)
    depth = np.ceil(np.log(np.maximum(hmax, 1.0)) / np.log(1.5))
    active = heights > 0
    first = np.where(active.any(axis=1), active.argmax(axis=1), 0)
    last = np.where(
        active.any(axis=1), n_cols - 1 - active[:, ::-1].argmax(axis=1), 0
    )
    span = (last - first + 1).astype(np.float64) * active.any(axis=1)
    cpd = T_NET_NS + T_LUT_NS * (1.0 + depth) + T_CARRY_NS * span
    power = P_BASE_MW + P_LUT_MW * (configs.astype(np.float64) @ act)
    pdp = power * cpd
    return np.stack([luts, cpd, power, pdp, pdp * luts], axis=1)


def ppa(configs: np.ndarray, operator: str, bits: int) -> np.ndarray:
    """Dispatch helper: ``operator`` in {"adder", "mult"}."""
    if operator == "adder":
        return adder_ppa(configs)
    if operator == "mult":
        return mult_ppa(configs, bits)
    raise ValueError(f"unknown operator kind: {operator}")
