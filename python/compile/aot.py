"""AOT export: lower every L2 graph to HLO text + weights + golden data.

Run once via ``make artifacts`` (``python -m compile.aot --out-dir
../artifacts``).  Python never runs again after this; the rust binary
consumes only the files written here.

Interchange format is **HLO text**, not serialized HloModuleProto: jax >=
0.5 emits protos with 64-bit instruction ids which the published ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).

Artifacts written:
  axo_eval_{add4,add8,add12,mul4,mul8}.hlo.txt  characterization graphs
  estimator_mul8.hlo.txt + estimator_mul8.weights.bin
  conss_mul4to8.hlo.txt + conss_mul4to8.weights.bin
  inputs_add12.bin       sampled 12-bit adder input pairs (u32 LE a then b)
  golden_behav.json      BEHAV+PPA fixtures pinning rust <-> python models
  manifest.json          shapes, dtypes, parameter order, target scaling

Weights .bin format (rust/src/runtime/weights.rs):
  magic "AXOW" | u32 version=1 | u32 n_tensors |
  per tensor: u32 name_len | name | u32 ndim | u32 dims[] | f32 data[] (LE)
"""

from __future__ import annotations

import argparse
import json
import struct
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as L2
from . import operator_model as om
from . import synth_model as sm
from . import train

GOLDEN_SEED = 99


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_weights_bin(path: Path, named_tensors: list[tuple[str, np.ndarray]]):
    with open(path, "wb") as f:
        f.write(b"AXOW")
        f.write(struct.pack("<II", 1, len(named_tensors)))
        for name, arr in named_tensors:
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


def flat_named_params(params, prefix: str):
    out = []
    for i, (w, b) in enumerate(params):
        out.append((f"{prefix}.layer{i}.w", np.asarray(w)))
        out.append((f"{prefix}.layer{i}.b", np.asarray(b)))
    return out


# ---------------------------------------------------------------------------
# Characterization graph exports
# ---------------------------------------------------------------------------

ADDER_EXPORTS = {
    # name: (n_bits, config_batch, n_inputs)
    "add4": (4, 16, 256),
    "add8": (8, 64, 65536),
    "add12": (12, 64, 65536),
}

MULT_EXPORTS = {
    # name: (m_bits, config_batch, n_inputs)
    "mul4": (4, 64, 256),
    "mul8": (8, 64, 65536),
}


def export_adder(name, n_bits, bsz, t, out_dir, manifest):
    cfg = jax.ShapeDtypeStruct((bsz, n_bits), jnp.int32)
    col = jax.ShapeDtypeStruct((t, 1), jnp.int32)
    lowered = jax.jit(L2.adder_eval).lower(cfg, col, col)
    path = out_dir / f"axo_eval_{name}.hlo.txt"
    path.write_text(to_hlo_text(lowered))
    manifest["executables"][f"axo_eval_{name}"] = {
        "hlo": path.name,
        "kind": "adder_eval",
        "bits": n_bits,
        "config_batch": bsz,
        "n_inputs": t,
        "inputs": [
            {"shape": [bsz, n_bits], "dtype": "i32", "role": "configs"},
            {"shape": [t, 1], "dtype": "i32", "role": "a"},
            {"shape": [t, 1], "dtype": "i32", "role": "b"},
        ],
        "output": {"shape": [bsz, 4], "dtype": "f32"},
    }


def export_mult(name, m_bits, bsz, t, out_dir, manifest):
    l = om.mult_config_len(m_bits)
    cfg = jax.ShapeDtypeStruct((bsz, l), jnp.float32)
    terms = jax.ShapeDtypeStruct((t, l), jnp.float32)
    exact = jax.ShapeDtypeStruct((t, 1), jnp.float32)
    lowered = jax.jit(L2.mult_eval).lower(cfg, terms, exact)
    path = out_dir / f"axo_eval_{name}.hlo.txt"
    path.write_text(to_hlo_text(lowered))
    manifest["executables"][f"axo_eval_{name}"] = {
        "hlo": path.name,
        "kind": "mult_eval",
        "bits": m_bits,
        "config_len": l,
        "config_batch": bsz,
        "n_inputs": t,
        "inputs": [
            {"shape": [bsz, l], "dtype": "f32", "role": "configs"},
            {"shape": [t, l], "dtype": "f32", "role": "terms"},
            {"shape": [t, 1], "dtype": "f32", "role": "exact"},
        ],
        "output": {"shape": [bsz, 4], "dtype": "f32"},
    }


# ---------------------------------------------------------------------------
# MLP exports (weights as runtime arguments)
# ---------------------------------------------------------------------------


def export_estimator(out_dir, manifest, epochs):
    res = train.train_estimator(epochs=epochs)
    bsz = 256
    arg_specs = [jax.ShapeDtypeStruct((bsz, 36), jnp.float32)]
    for w, b in res.params:
        arg_specs.append(jax.ShapeDtypeStruct(tuple(w.shape), jnp.float32))
        arg_specs.append(jax.ShapeDtypeStruct(tuple(b.shape), jnp.float32))
    lowered = jax.jit(L2.estimator_fwd).lower(*arg_specs)
    (out_dir / "estimator_mul8.hlo.txt").write_text(to_hlo_text(lowered))
    named = flat_named_params(res.params, "estimator")
    write_weights_bin(out_dir / "estimator_mul8.weights.bin", named)
    manifest["executables"]["estimator_mul8"] = {
        "hlo": "estimator_mul8.hlo.txt",
        "weights": "estimator_mul8.weights.bin",
        "kind": "estimator",
        "config_batch": bsz,
        "param_order": [n for n, _ in named],
        "inputs": [{"shape": [bsz, 36], "dtype": "f32", "role": "configs"}],
        "output": {"shape": [bsz, 2], "dtype": "f32"},
        "targets": ["pdplut", "avg_abs_rel_err"],
        "target_min": [float(v) for v in res.x_min],
        "target_max": [float(v) for v in res.x_max],
        "train_loss": res.history[-1] if res.history else None,
    }


def export_conss(out_dir, manifest, epochs):
    res = train.train_conss(epochs=epochs)
    bsz = 256
    fin = 10 + L2.CONSS_NOISE_BITS
    arg_specs = [jax.ShapeDtypeStruct((bsz, fin), jnp.float32)]
    for w, b in res.params:
        arg_specs.append(jax.ShapeDtypeStruct(tuple(w.shape), jnp.float32))
        arg_specs.append(jax.ShapeDtypeStruct(tuple(b.shape), jnp.float32))
    lowered = jax.jit(L2.conss_fwd).lower(*arg_specs)
    (out_dir / "conss_mul4to8.hlo.txt").write_text(to_hlo_text(lowered))
    named = flat_named_params(res.params, "conss")
    write_weights_bin(out_dir / "conss_mul4to8.weights.bin", named)
    manifest["executables"]["conss_mul4to8"] = {
        "hlo": "conss_mul4to8.hlo.txt",
        "weights": "conss_mul4to8.weights.bin",
        "kind": "conss",
        "config_batch": bsz,
        "noise_bits": L2.CONSS_NOISE_BITS,
        "param_order": [n for n, _ in named],
        "inputs": [{"shape": [bsz, fin], "dtype": "f32", "role": "l_config+noise"}],
        "output": {"shape": [bsz, 36], "dtype": "f32"},
        "train_loss": res.history[-1] if res.history else None,
    }


# ---------------------------------------------------------------------------
# Golden fixtures + shared input sets
# ---------------------------------------------------------------------------


def golden_configs(length: int, n_random: int = 10) -> list[int]:
    """Accurate + single-removal + seeded random UINT configurations."""
    vals = [(1 << length) - 1]  # accurate
    vals += [((1 << length) - 1) ^ (1 << k) for k in (0, length // 2, length - 1)]
    rng = np.random.default_rng(GOLDEN_SEED)
    vals += [int(v) for v in rng.integers(1, 1 << length, size=n_random, dtype=np.uint64)]
    return sorted(set(vals))


def build_golden(out_dir: Path):
    golden = {"operators": {}}
    # Adders
    for name, (n_bits, _, _) in ADDER_EXPORTS.items():
        a, b = om.adder_inputs(n_bits)
        uints = golden_configs(n_bits if n_bits <= 8 else 12)
        cfgs = np.stack([om.config_from_uint(v, n_bits) for v in uints])
        behav = om.behav_metrics(om.adder_exact(a, b), om.adder_eval(cfgs, a, b))
        ppa = sm.adder_ppa(cfgs)
        golden["operators"][name] = _golden_entry(uints, behav, ppa)
    # Multipliers
    for name, (m_bits, _, _) in MULT_EXPORTS.items():
        a, b = om.mult_inputs(m_bits)
        terms = om.mult_term_matrix(m_bits, a, b)
        length = om.mult_config_len(m_bits)
        uints = golden_configs(length)
        cfgs = np.stack([om.config_from_uint(v, length) for v in uints])
        behav = om.behav_metrics(om.mult_exact(terms), om.mult_eval(cfgs, terms))
        ppa = sm.mult_ppa(cfgs, m_bits)
        golden["operators"][name] = _golden_entry(uints, behav, ppa)
    (out_dir / "golden_behav.json").write_text(json.dumps(golden, indent=1))


def _golden_entry(uints, behav, ppa):
    return {
        "configs_uint": [str(v) for v in uints],
        "behav_metrics": list(om.BEHAV_METRICS),
        "behav": [[float(x) for x in row] for row in behav],
        "ppa_metrics": list(sm.PPA_METRICS),
        "ppa": [[float(x) for x in row] for row in ppa],
    }


def write_add12_inputs(out_dir: Path):
    a, b = om.adder_inputs(12)
    with open(out_dir / "inputs_add12.bin", "wb") as f:
        f.write(b"AXIN")
        f.write(struct.pack("<II", 1, len(a)))
        f.write(a.astype("<u4").tobytes())
        f.write(b.astype("<u4").tobytes())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file marker path")
    ap.add_argument("--estimator-epochs", type=int, default=40)
    ap.add_argument("--conss-epochs", type=int, default=30)
    ap.add_argument("--skip-train", action="store_true",
                    help="export characterization graphs + golden only")
    args = ap.parse_args()
    out_dir = Path(args.out).parent if args.out else Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest: dict = {"version": 1, "executables": {}}
    for name, (n_bits, bsz, t) in ADDER_EXPORTS.items():
        export_adder(name, n_bits, bsz, t, out_dir, manifest)
        print(f"exported axo_eval_{name}")
    for name, (m_bits, bsz, t) in MULT_EXPORTS.items():
        export_mult(name, m_bits, bsz, t, out_dir, manifest)
        print(f"exported axo_eval_{name}")
    if not args.skip_train:
        export_estimator(out_dir, manifest, args.estimator_epochs)
        print("exported estimator_mul8")
        export_conss(out_dir, manifest, args.conss_epochs)
        print("exported conss_mul4to8")
    build_golden(out_dir)
    write_add12_inputs(out_dir)
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if args.out:
        # Makefile dependency marker (model.hlo.txt): alias of mul8 graph.
        (Path(args.out)).write_text((out_dir / "axo_eval_mul8.hlo.txt").read_text())
    print(f"artifacts written to {out_dir}")


if __name__ == "__main__":
    main()
