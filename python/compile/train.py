"""Build-time training of the surrogate estimator and ConSS generator MLPs.

The paper uses AutoML (MLJAR -> CatBoost/LightGBM) for PPA/BEHAV estimation
and a scikit RandomForest for ConSS; the rust crate implements both tree
ensembles natively (``rust/src/ml/``).  This module trains the *MLP*
variants whose AOT-compiled forwards run on the GA hot path via PJRT:

  * Estimator: 36-bit multiplier configuration -> min-max-scaled
    [PDPLUT, AVG_ABS_REL_ERR].  Trained on a seeded random sample of the
    8x8 signed-multiplier space characterized with the canonical
    operator + synthesis models (the same data-generating process the rust
    pipeline uses).
  * ConSS generator: 10-bit 4x4 configuration + noise bits -> 36 bit
    probabilities, trained on the Euclidean distance-matched dataset
    (``matching.py``).

Pure-jnp forward/backward with Adam; the Pallas forward is numerically
pinned to the jnp forward by pytest, so the trained weights transfer.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from . import matching
from . import operator_model as om
from . import synth_model as sm
from .kernels import ref
from .model import CONSS_LAYERS, CONSS_NOISE_BITS, ESTIMATOR_LAYERS

TRAIN_SAMPLE_MUL8 = 10650  # paper §V-B: sampled points of the 68.7e9 space
SEED = 2023


# ---------------------------------------------------------------------------
# Dataset generation (mirrors rust/src/charac but on the numpy model)
# ---------------------------------------------------------------------------


def sample_mul8_configs(n: int = TRAIN_SAMPLE_MUL8, seed: int = SEED) -> np.ndarray:
    """Seeded unique random sample of non-zero 36-bit configurations."""
    rng = np.random.default_rng(seed)
    seen: set[int] = set()
    out = []
    while len(out) < n:
        v = int(rng.integers(1, 1 << 36))
        if v not in seen:
            seen.add(v)
            out.append(om.config_from_uint(v, 36))
    return np.stack(out)


def characterize_mul(configs: np.ndarray, m_bits: int, chunk: int = 256) -> np.ndarray:
    """(B, 2) [PDPLUT, AVG_ABS_REL_ERR] — the paper's headline metric pair.

    Chunked over configurations: the (chunk, T) error plane for the 8x8
    multiplier's 65536-pair input space stays ~128 MB instead of gigabytes.
    """
    a, b = om.mult_inputs(m_bits)
    terms = om.mult_term_matrix(m_bits, a, b)
    exact = om.mult_exact(terms)
    rows = []
    for s in range(0, configs.shape[0], chunk):
        c = configs[s : s + chunk]
        rows.append(om.behav_metrics(exact, om.mult_eval(c, terms)))
    behav = np.concatenate(rows)
    ppa = sm.mult_ppa(configs, m_bits)
    return np.stack([ppa[:, 4], behav[:, 1]], axis=1)


# ---------------------------------------------------------------------------
# MLP training (plain jnp, Adam)
# ---------------------------------------------------------------------------


def init_params(layer_shapes, key):
    params = []
    for fan_in, fan_out in layer_shapes:
        key, wk = jax.random.split(key)
        scale = jnp.sqrt(2.0 / fan_in)
        params.append(
            (
                jax.random.normal(wk, (fan_in, fan_out), jnp.float32) * scale,
                jnp.zeros((fan_out,), jnp.float32),
            )
        )
    return params


def _adam_update(params, grads, state, lr, step, b1=0.9, b2=0.999, eps=1e-8):
    m, v = state
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mh = jax.tree.map(lambda a: a / (1 - b1**step), m)
    vh = jax.tree.map(lambda a: a / (1 - b2**step), v)
    params = jax.tree.map(lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), params, mh, vh)
    return params, (m, v)


@dataclass
class TrainResult:
    params: list
    history: list[float] = field(default_factory=list)
    x_min: np.ndarray | None = None  # target scaling (estimator only)
    x_max: np.ndarray | None = None


def _train(x, y, layer_shapes, loss_kind, epochs, batch, lr, seed):
    key = jax.random.PRNGKey(seed)
    params = init_params(layer_shapes, key)
    zeros = jax.tree.map(jnp.zeros_like, params)
    state = (zeros, jax.tree.map(jnp.zeros_like, params))

    def loss_fn(p, xb, yb):
        out = ref.mlp_ref(xb, p, final_sigmoid=False)
        if loss_kind == "mse":
            return jnp.mean((out - yb) ** 2)
        # BCE with logits (ConSS): stable formulation.
        return jnp.mean(jnp.maximum(out, 0) - out * yb + jnp.log1p(jnp.exp(-jnp.abs(out))))

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    n = x.shape[0]
    rng = np.random.default_rng(seed)
    result = TrainResult(params=params)
    step = 0
    for _ in range(epochs):
        perm = rng.permutation(n)
        epoch_loss = 0.0
        nb = 0
        for s in range(0, n - batch + 1, batch):
            xb = jnp.asarray(x[perm[s : s + batch]])
            yb = jnp.asarray(y[perm[s : s + batch]])
            step += 1
            lval, grads = grad_fn(params, xb, yb)
            params, state = _adam_update(params, grads, state, lr, step)
            epoch_loss += float(lval)
            nb += 1
        result.history.append(epoch_loss / max(nb, 1))
    result.params = params
    return result


def train_estimator(
    configs: np.ndarray | None = None,
    targets: np.ndarray | None = None,
    epochs: int = 60,
    batch: int = 256,
    lr: float = 1e-3,
) -> TrainResult:
    """Train the 8x8-multiplier PPA/BEHAV estimator on scaled targets."""
    if configs is None:
        configs = sample_mul8_configs()
    if targets is None:
        targets = characterize_mul(configs, 8)
    lo = targets.min(axis=0)
    hi = targets.max(axis=0)
    span = np.where(hi > lo, hi - lo, 1.0)
    y = ((targets - lo) / span).astype(np.float32)
    x = configs.astype(np.float32)
    res = _train(x, y, ESTIMATOR_LAYERS, "mse", epochs, batch, lr, seed=SEED)
    res.x_min, res.x_max = lo, hi
    return res


def train_conss(
    epochs: int = 40, batch: int = 256, lr: float = 1e-3,
    h_configs: np.ndarray | None = None, h_metrics: np.ndarray | None = None,
) -> TrainResult:
    """Train the 4x4 -> 8x8 ConSS generator on matched pairs + noise bits."""
    l_configs = om.all_configs(10)
    l_metrics = characterize_mul(l_configs, 4)
    if h_configs is None:
        h_configs = sample_mul8_configs(2048, seed=SEED + 1)
        h_metrics = characterize_mul(h_configs, 8)
    x, y = matching.conss_dataset(
        l_configs, l_metrics, h_configs, h_metrics, CONSS_NOISE_BITS
    )
    return _train(x, y, CONSS_LAYERS, "bce", epochs, batch, lr, seed=SEED + 2)
