"""L2: JAX compute graphs composing the Pallas kernels.

These are the functions that get AOT-lowered to HLO text by ``aot.py`` and
executed from the rust runtime:

  * ``adder_eval`` / ``mult_eval`` — batched characterization graphs that
    wrap the L1 ``axo_eval`` kernels and finalize the metric accumulators
    into (avg_abs_err, avg_abs_rel_err, max_abs_err, err_prob).
  * ``estimator_fwd`` — PPA/BEHAV surrogate MLP forward (36 -> 64 -> 64 -> 2,
    predicting min-max-scaled [PDPLUT, AVG_ABS_REL_ERR]).
  * ``conss_fwd`` — ConSS generator MLP forward
    (10 + NOISE_BITS -> 128 -> 36 sigmoid bit probabilities).

Weights are runtime arguments (flat (w, b) list order, see ``aot.py``
manifest), so the rust side can hot-swap retrained weights without
re-lowering.  Training lives in ``train.py``; it uses the plain-jnp
reference forward (`ref.mlp_ref`) which pytest pins against the Pallas
forward used here.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import axo_eval, mlp

# Architecture constants (shared with train.py / aot.py / rust manifest).
ESTIMATOR_LAYERS = ((36, 64), (64, 64), (64, 2))
CONSS_NOISE_BITS = 4
CONSS_LAYERS = ((10 + CONSS_NOISE_BITS, 128), (128, 36))


def _finalize_metrics(raw: jnp.ndarray, n_inputs: int) -> jnp.ndarray:
    """sums/counts -> means; column order matches operator_model.BEHAV_METRICS."""
    t = jnp.float32(n_inputs)
    return jnp.stack(
        [raw[:, 0] / t, raw[:, 1] / t, raw[:, 2], raw[:, 3] / t], axis=1
    )


def adder_eval(configs: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(B, 4) BEHAV metrics for unsigned-adder configurations.

    configs: (B, N) i32; a, b: (T, 1) i32 operand columns.
    """
    raw = axo_eval.adder_eval_kernel(configs, a, b)
    return _finalize_metrics(raw, a.shape[0])


def mult_eval(configs: jnp.ndarray, terms: jnp.ndarray, exact: jnp.ndarray) -> jnp.ndarray:
    """(B, 4) BEHAV metrics for signed-multiplier configurations.

    configs: (B, L) f32 0/1; terms: (T, L) f32; exact: (T, 1) f32.
    """
    raw = axo_eval.mult_eval_kernel(configs, terms, exact)
    return _finalize_metrics(raw, terms.shape[0])


def _params_from_flat(flat, layer_shapes):
    """Reassemble [(w, b), ...] from the flat argument list used in HLO."""
    params = []
    it = iter(flat)
    for _ in layer_shapes:
        w = next(it)
        b = next(it)
        params.append((w, b))
    return params


def estimator_fwd(x: jnp.ndarray, *flat_params: jnp.ndarray) -> jnp.ndarray:
    """Surrogate PPA/BEHAV estimator forward (scaled outputs)."""
    params = _params_from_flat(flat_params, ESTIMATOR_LAYERS)
    return mlp.mlp_forward(x, params, final_sigmoid=False)


def conss_fwd(x: jnp.ndarray, *flat_params: jnp.ndarray) -> jnp.ndarray:
    """ConSS generator forward: (B, 10+NOISE) -> (B, 36) bit probabilities."""
    params = _params_from_flat(flat_params, CONSS_LAYERS)
    return mlp.mlp_forward(x, params, final_sigmoid=True)
