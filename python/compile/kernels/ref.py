"""Pure-jnp oracles for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` ops only.  pytest asserts
``assert_allclose(kernel(...), ref(...))`` across shapes/dtypes (hypothesis
sweeps in ``python/tests``), and these references are themselves checked
against the canonical numpy operator model in
``python/compile/operator_model.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Metric column order shared across kernel / ref / rust:
#   0: sum |err|         (divide by T outside for avg_abs_err)
#   1: sum |err|/max(|exact|,1)   (-> avg_abs_rel_err)
#   2: max |err|
#   3: count err != 0    (-> err_prob)
N_METRICS = 4


def adder_outputs_ref(configs: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(B, T) approximate sums. Mirrors operator_model.adder_eval in jnp."""
    n_bits = configs.shape[1]
    a = a.astype(jnp.int32)[None, :]
    b = b.astype(jnp.int32)[None, :]
    cfg = configs.astype(jnp.int32)
    carry = jnp.zeros((configs.shape[0], a.shape[1]), dtype=jnp.int32)
    out = jnp.zeros_like(carry)
    for i in range(n_bits):
        ai = (a >> i) & 1
        bi = (b >> i) & 1
        p = (ai ^ bi) * cfg[:, i][:, None]
        s = p ^ carry
        out = out + (s << i)
        carry = jnp.where(p == 1, carry, bi)
    return out + (carry << n_bits)


def metrics_ref(exact: jnp.ndarray, approx: jnp.ndarray) -> jnp.ndarray:
    """(B, 4) raw metric accumulators (sums / max / count), float32."""
    err = jnp.abs(exact[None, :].astype(jnp.float32) - approx.astype(jnp.float32))
    denom = jnp.maximum(jnp.abs(exact.astype(jnp.float32)), 1.0)[None, :]
    return jnp.stack(
        [
            err.sum(axis=1),
            (err / denom).sum(axis=1),
            err.max(axis=1),
            (err > 0).sum(axis=1).astype(jnp.float32),
        ],
        axis=1,
    )


def adder_eval_ref(configs: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Reference for kernels.axo_eval.adder_eval_kernel."""
    exact = (a + b).astype(jnp.int32)
    return metrics_ref(exact, adder_outputs_ref(configs, a, b))


def mult_eval_ref(configs: jnp.ndarray, terms: jnp.ndarray) -> jnp.ndarray:
    """Reference for kernels.axo_eval.mult_eval_kernel.

    ``terms`` is (T, L) float32 (exactly representable: |term| < 2^15 and
    row sums < 2^15 for M <= 8).  approx = configs @ terms.T.
    """
    cfg = configs.astype(jnp.float32)
    approx = cfg @ terms.T
    exact = terms.sum(axis=1)
    err = jnp.abs(exact[None, :] - approx)
    denom = jnp.maximum(jnp.abs(exact), 1.0)[None, :]
    return jnp.stack(
        [
            err.sum(axis=1),
            (err / denom).sum(axis=1),
            err.max(axis=1),
            (err > 0).sum(axis=1).astype(jnp.float32),
        ],
        axis=1,
    )


def mlp_ref(x: jnp.ndarray, params: list[tuple[jnp.ndarray, jnp.ndarray]],
            final_sigmoid: bool = False) -> jnp.ndarray:
    """Reference MLP forward: relu hidden layers, linear/sigmoid output."""
    h = x
    for w, b in params[:-1]:
        h = jax.nn.relu(h @ w + b)
    w, b = params[-1]
    out = h @ w + b
    return jax.nn.sigmoid(out) if final_sigmoid else out
