"""Pallas kernels for batched approximate-operator characterization (L1).

The characterization sweep — evaluate B approximate configurations against T
input pairs and reduce to error statistics — is the compute hot-spot of the
AxOCS pipeline (paper §V characterizes up to 10,650 36-bit multiplier
configurations over the full 2^16 signed input space).

TPU mapping (DESIGN.md §Hardware-Adaptation): configurations tile into VMEM
along the grid's first axis, the input space streams through as reduction
tiles along the second, and the four error statistics accumulate in the
revisited output block.  For the multiplier the inner product
``configs @ terms.T`` is an MXU-shaped f32 matmul (every partial-product
term and every exact product is < 2^15 in magnitude for M <= 8, so f32 is
exact).  For the adder the carry recurrence is an N-step unrolled loop of
VPU bit ops over the (config-block x input-tile) plane.

All kernels run ``interpret=True`` — real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.  Correctness is pinned
against ``ref.py`` (pure jnp) and the canonical numpy operator model.

Metric columns (raw accumulators; divide by T outside the kernel):
  0: sum |err|    1: sum |err|/max(|exact|,1)    2: max |err|    3: #(err!=0)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_METRICS = 4

# Default tile sizes (§Perf L1-1).  Config blocks of 64 with 16384-deep
# input tiles keep the (BB, TT) error plane at 64x16384 f32 = 4 MiB plus a
# (TT, L) terms tile of 16384x36 f32 = 2.25 MiB — ~6.3 MiB live, inside a
# 16 MiB VMEM budget with double-buffering headroom, while quartering the
# grid-step count relative to the original 4096 tile (fewer, larger MXU
# matmuls; measured 1.36x faster on the CPU PJRT backend too).
DEFAULT_CONFIG_BLOCK = 64
DEFAULT_INPUT_TILE = 16384


def _metric_update(out_ref, err: jnp.ndarray, rel: jnp.ndarray, first: jnp.ndarray):
    """Accumulate the four statistics into the revisited output block."""

    @pl.when(first)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[:, 0] += err.sum(axis=1)
    out_ref[:, 1] += rel.sum(axis=1)
    out_ref[:, 2] = jnp.maximum(out_ref[:, 2], err.max(axis=1))
    out_ref[:, 3] += (err > 0).sum(axis=1).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Signed multiplier: approx = configs @ terms.T (MXU path)
# ---------------------------------------------------------------------------


def _mult_kernel(cfg_ref, terms_ref, exact_ref, out_ref):
    cfg = cfg_ref[...]  # (BB, L) f32
    terms = terms_ref[...]  # (TT, L) f32
    exact = exact_ref[...][:, 0]  # (TT,)
    approx = jax.lax.dot_general(
        cfg,
        terms,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (BB, TT)
    err = jnp.abs(exact[None, :] - approx)
    rel = err / jnp.maximum(jnp.abs(exact), 1.0)[None, :]
    _metric_update(out_ref, err, rel, pl.program_id(1) == 0)


def mult_eval_kernel(
    configs: jnp.ndarray,
    terms: jnp.ndarray,
    exact: jnp.ndarray,
    *,
    config_block: int = DEFAULT_CONFIG_BLOCK,
    input_tile: int = DEFAULT_INPUT_TILE,
) -> jnp.ndarray:
    """Raw (B, 4) error statistics for signed-multiplier configurations.

    Args:
        configs: (B, L) f32 0/1 configuration matrix; B % config_block == 0.
        terms:   (T, L) f32 per-LUT signed partial-product contributions.
        exact:   (T, 1) f32 exact products (= terms.sum(1), precomputed so
                 the reduction is not re-done per config block).
    """
    b, l = configs.shape
    t = terms.shape[0]
    bb = min(config_block, b)
    tt = min(input_tile, t)
    assert b % bb == 0 and t % tt == 0, (b, bb, t, tt)
    grid = (b // bb, t // tt)
    return pl.pallas_call(
        _mult_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, l), lambda ib, it: (ib, 0)),
            pl.BlockSpec((tt, l), lambda ib, it: (it, 0)),
            pl.BlockSpec((tt, 1), lambda ib, it: (it, 0)),
        ],
        out_specs=pl.BlockSpec((bb, N_METRICS), lambda ib, it: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((b, N_METRICS), jnp.float32),
        interpret=True,
    )(configs, terms, exact)


# ---------------------------------------------------------------------------
# Unsigned adder: carry recurrence (VPU path)
# ---------------------------------------------------------------------------


def _adder_kernel(cfg_ref, a_ref, b_ref, out_ref, *, n_bits: int):
    cfg = cfg_ref[...]  # (BB, N) i32
    a = a_ref[...][:, 0][None, :]  # (1, TT) i32
    b = b_ref[...][:, 0][None, :]
    carry = jnp.zeros((cfg.shape[0], a.shape[1]), dtype=jnp.int32)
    out = jnp.zeros_like(carry)
    # N is static (<= 12): unrolled ripple over the (BB, TT) plane.
    for i in range(n_bits):
        ai = (a >> i) & 1
        bi = (b >> i) & 1
        p = (ai ^ bi) * cfg[:, i][:, None]
        s = p ^ carry
        out = out + (s << i)
        carry = jnp.where(p == 1, carry, bi)
    approx = (out + (carry << n_bits)).astype(jnp.float32)
    exact = (a + b).astype(jnp.float32)  # (1, TT)
    err = jnp.abs(exact - approx)
    rel = err / jnp.maximum(jnp.abs(exact), 1.0)
    _metric_update(out_ref, err, rel, pl.program_id(1) == 0)


def adder_eval_kernel(
    configs: jnp.ndarray,
    a: jnp.ndarray,
    b: jnp.ndarray,
    *,
    config_block: int = DEFAULT_CONFIG_BLOCK,
    input_tile: int = DEFAULT_INPUT_TILE,
) -> jnp.ndarray:
    """Raw (B, 4) error statistics for unsigned-adder configurations.

    Args:
        configs: (B, N) i32 0/1 configuration matrix.
        a, b:    (T, 1) i32 operand columns.
    """
    bsz, n_bits = configs.shape
    t = a.shape[0]
    bb = min(config_block, bsz)
    tt = min(input_tile, t)
    assert bsz % bb == 0 and t % tt == 0, (bsz, bb, t, tt)
    grid = (bsz // bb, t // tt)
    return pl.pallas_call(
        functools.partial(_adder_kernel, n_bits=n_bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n_bits), lambda ib, it: (ib, 0)),
            pl.BlockSpec((tt, 1), lambda ib, it: (it, 0)),
            pl.BlockSpec((tt, 1), lambda ib, it: (it, 0)),
        ],
        out_specs=pl.BlockSpec((bb, N_METRICS), lambda ib, it: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, N_METRICS), jnp.float32),
        interpret=True,
    )(configs, a, b)
