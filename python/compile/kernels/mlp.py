"""Pallas tiled MLP forward (L1) — the GA-path surrogate hot loop.

During GA-based DSE the rust coordinator batches fitness requests and
executes the AOT-compiled surrogate MLP via PJRT; this module provides the
kernel that lowers into that executable.  Each dense layer is a Pallas
kernel tiled over the batch dimension: the weight matrix (<= 64x64 here)
stays resident in VMEM across batch tiles while activations stream through
— the canonical MXU schedule for skinny inference matmuls.

Weights are *runtime arguments* (not baked constants): python trains and
writes ``artifacts/*.weights.bin``; rust loads them once and passes them as
PJRT literals, so retraining never requires re-lowering.

``interpret=True`` as everywhere (CPU PJRT cannot execute Mosaic calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BATCH_TILE = 64

ACT_LINEAR = 0
ACT_RELU = 1
ACT_SIGMOID = 2


def _linear_kernel(x_ref, w_ref, b_ref, out_ref, *, activation: int):
    x = x_ref[...]  # (BB, F)
    w = w_ref[...]  # (F, O)
    b = b_ref[...]  # (1, O)
    y = (
        jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        + b
    )
    if activation == ACT_RELU:
        y = jnp.maximum(y, 0.0)
    elif activation == ACT_SIGMOID:
        y = jax.nn.sigmoid(y)
    out_ref[...] = y


def linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    *,
    activation: int = ACT_LINEAR,
    batch_tile: int = DEFAULT_BATCH_TILE,
) -> jnp.ndarray:
    """One dense layer ``act(x @ w + b)`` tiled over the batch dimension."""
    bsz, f = x.shape
    f2, o = w.shape
    assert f == f2, (f, f2)
    bb = min(batch_tile, bsz)
    assert bsz % bb == 0, (bsz, bb)
    b2 = b.reshape(1, o)
    return pl.pallas_call(
        functools.partial(_linear_kernel, activation=activation),
        grid=(bsz // bb,),
        in_specs=[
            pl.BlockSpec((bb, f), lambda ib: (ib, 0)),
            pl.BlockSpec((f, o), lambda ib: (0, 0)),
            pl.BlockSpec((1, o), lambda ib: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, o), lambda ib: (ib, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, o), jnp.float32),
        interpret=True,
    )(x, w, b2)


def mlp_forward(
    x: jnp.ndarray,
    params: list[tuple[jnp.ndarray, jnp.ndarray]],
    *,
    final_sigmoid: bool = False,
    batch_tile: int = DEFAULT_BATCH_TILE,
) -> jnp.ndarray:
    """Full MLP forward: relu hidden layers, linear or sigmoid output."""
    h = x
    for w, b in params[:-1]:
        h = linear(h, w, b, activation=ACT_RELU, batch_tile=batch_tile)
    w, b = params[-1]
    act = ACT_SIGMOID if final_sigmoid else ACT_LINEAR
    return linear(h, w, b, activation=act, batch_tile=batch_tile)
